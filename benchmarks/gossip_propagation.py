"""Gossip overlay benchmarks: the sync fast path + propagation sweeps.

Fast-path measurements (machine-readable copy in ``BENCH_gossip_sync.json``):

* ``gossip/sync_round/...`` — wall time of ONE anti-entropy round across
  impl ("scan" = PR-1 vmap-over-scan fold, "fused" = winner-reduction
  kernel path) x N x capacity, with a bitwise equivalence check;
* ``gossip/dispatch_batching`` — device dispatches per simulated second of
  a 25-node ``run_dagfl_gossip`` sim: the PR-1 host loop issued two jitted
  calls per sync tick (edge sampler + round); the tick-batched ``advance``
  / while-loop ``converge`` issue one call per window.

Accuracy sweeps (claims validated at bench scale):

* sync period -> 0, drop 0 recovers the shared-ledger curve (ideal limit);
* slower sync / lossier links leave replicas further behind the union view
  (``max_missing`` rows) without destabilizing training;
* a mid-run partition grows divergence that collapses again after healing.

``python -m benchmarks.gossip_propagation --smoke`` runs a reduced grid and
FAILS (exit 1) if the fused round loses bitwise equivalence with the scan
round, drops below a 2x speedup, the mesh round diverges from the fused
one, bank gossip at unlimited capacity diverges from the bankless path,
the event engine's degenerate uniform-delay limit diverges from the tick
path, an obs-instrumented run diverges from the obs-off path, the warmed
obs collectors cost more than 10% wall time, an all-honest fault config
diverges from the un-faulted path, a spoofed chunk survives digest
verification into a gated view, the identity delta codec diverges from
the uncompressed bank path, a compressed codec falls below a 2x byte
reduction on the constrained 1 Mbps class, the zero-rate serving config
diverges from the serve-free path, the ideal-wire serving arm serves
zero requests, a histogram-instrumented run diverges from the obs-off
path, or the warmed histogram collectors cost more than 10% wall time —
the CI tripwires.
It also exports the last obs-on run as
``bench_artifacts/obs_sample.trace.json`` (the Perfetto-loadable
artifact CI uploads; the directory is untracked — bench outputs never
land in the repo).
"""
import argparse
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, fmt_curve, timed
from repro.core import dag as dag_lib
from repro.fl.experiments import default_dagfl_config, make_cnn_setup
from repro.fl.systems import SimConfig, run_dagfl, run_dagfl_gossip
from repro.net import gossip as gossip_lib
from repro.net import mesh as mesh_lib
from repro.net import replica as replica_lib
from repro.net import topology as topo
from repro.kernels.delta_codec import DeltaCodec
from repro.net.bank import BankGossipConfig
from repro.net.faults import ROLE_HONEST, ROLE_SPOOF, FaultConfig
from repro.obs import HistConfig, ObsConfig, write_chrome_trace

# Bench sample artifacts land in an UNTRACKED output dir (gitignored);
# CI uploads them from there instead of committing them at the repo root.
ARTIFACT_DIR = "bench_artifacts"
TRACE_SAMPLE_PATH = os.path.join(ARTIFACT_DIR, "obs_sample.trace.json")

JSON_PATH = "BENCH_gossip_sync.json"


def _emit_result(tag: str, res, wall_s: float, iterations: int) -> None:
    miss = res.extras.get("missing_rows_final")
    extra = (
        f"final_acc={res.accs[-1]:.3f};sync_rounds={res.extras.get('sync_rounds', 0)};"
        f"max_missing={int(miss.max()) if miss is not None else 0};"
        f"dup_approvals={res.extras.get('approvals_issued', 0) - res.extras.get('approvals_in_union', 0)};"
        f"curve={fmt_curve(res.iters, res.accs)}"
    )
    emit(tag, (wall_s / max(iterations, 1)) * 1e6, extra)


# ---------------------------------------------------------------------------
# Sync fast path: impl x N x cap round-timing grid
# ---------------------------------------------------------------------------


def _half_full_replicas(num_nodes: int, capacity: int, seed: int):
    """Realistic occupancy: a half-full ledger replicated N ways."""
    dag = dag_lib.empty_dag(capacity, 2, num_nodes + 1)
    rng = np.random.default_rng(seed)
    for i in range(capacity // 2):
        dag = dag_lib.publish(
            dag, jnp.asarray(int(rng.integers(0, num_nodes)), jnp.int32),
            jnp.float32(i * 0.5), jnp.full((2,), dag_lib.NO_TX, jnp.int32),
            jnp.float32(0.5), jnp.float32(0.0), jnp.asarray(i, jnp.int32),
        )
    return replica_lib.init_replicas(
        dag, bank=jnp.zeros((capacity, 8)), num_replicas=num_nodes
    )


def _time_round(round_fn, dags, edges, reps: int) -> float:
    out = round_fn(dags, edges)                          # compile
    jax.block_until_ready(out.publisher)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = round_fn(out, edges)
    jax.block_until_ready(out.publisher)
    return (time.perf_counter() - t0) / reps


def run_sync_round_grid(
    ns=(25, 100), caps=(64, 256), impls=("scan", "fused"),
    reps: int = 20, seed: int = 0, record: dict = None,
):
    """Wall time of ONE anti-entropy round per (impl, N, cap), plus a
    bitwise scan-vs-fused equivalence check on every grid point."""
    rows = []
    for n in ns:
        top = topo.k_regular(n, 4, seed=seed)
        edges = jnp.asarray(top.adjacency)
        for cap in caps:
            rs = _half_full_replicas(n, cap, seed)
            outs, per_impl = {}, {}
            for impl in impls:
                fn = gossip_lib.make_gossip_round(impl)
                # the scan path is the slow one; fewer reps keep the grid fast
                r = max(3, reps // 4) if impl == "scan" else reps
                per_call = _time_round(fn, rs.dags, edges, r)
                outs[impl] = fn(rs.dags, edges)
                per_impl[impl] = per_call
                emit(
                    f"gossip/sync_round/{impl}/n{n}_cap{cap}",
                    per_call * 1e6, f"reps={r}",
                )
                rows.append(dict(impl=impl, n=n, cap=cap, us_per_call=per_call * 1e6))
            equivalent = all(
                bool(gossip_lib.trees_equal_jit(outs[i], outs[impls[0]]))
                for i in impls[1:]
            )
            speedup = per_impl[impls[0]] / per_impl[impls[-1]]
            emit(
                f"gossip/sync_round/speedup/n{n}_cap{cap}", speedup,
                f"bitwise_equivalent={equivalent}",
            )
            rows[-1]["speedup_vs_" + impls[0]] = speedup
            rows[-1]["bitwise_equivalent"] = equivalent
    if record is not None:
        record["sync_round"] = rows
    return rows


def run_sharded_sync(
    n: int = 48, cap: int = 128, reps: int = 10, seed: int = 0,
    record: dict = None,
):
    """Mesh-sharded round vs the single-device fused round.

    When >1 device is visible (the CI 8-device lane forces eight host CPU
    devices), runs the fused round with the ReplicaSet receiver axis sharded
    over every viable ("nodes", "model") mesh and asserts BITWISE equality
    with the single-device fused output; wall times land next to the
    single-device number in ``BENCH_gossip_sync.json``. Single-device runs
    record a skip marker so the JSON says why the entry is absent.
    """
    d = jax.device_count()
    rows = []
    if d < 2:
        if record is not None:
            record["sharded_sync"] = dict(skipped=f"{d} device(s) visible")
        return rows
    shapes = [(d, 1)]
    if d > 2 and d % 2 == 0:
        shapes.append((2, d // 2))
    top = topo.k_regular(n, 4, seed=seed)
    edges = jnp.asarray(top.adjacency)
    rs = _half_full_replicas(n, cap, seed)
    fused = gossip_lib.make_gossip_round("fused")
    base = fused(rs.dags, edges)
    base_us = _time_round(fused, rs.dags, edges, reps) * 1e6
    for nodes, model in shapes:
        mesh = mesh_lib.make_gossip_mesh(nodes=nodes, model=model)
        fn = gossip_lib.make_gossip_round("fused", mesh=mesh)
        equivalent = bool(gossip_lib.trees_equal_jit(fn(rs.dags, edges), base))
        per_us = _time_round(fn, rs.dags, edges, reps) * 1e6
        emit(
            f"gossip/sharded_round/{nodes}x{model}/n{n}_cap{cap}", per_us,
            f"bitwise_equal_fused={equivalent};single_device_us={base_us:.1f}",
        )
        rows.append(dict(
            mesh=f"{nodes}x{model}", n=n, cap=cap, us_per_call=per_us,
            single_device_us=base_us, bitwise_equal_fused=equivalent,
        ))
    if record is not None:
        record["sharded_sync"] = rows
    return rows


def run_dispatch_batching(
    iterations: int = 150, num_nodes: int = 25, seed: int = 0, record: dict = None,
):
    """Device dispatches per simulated second, 25-node end-to-end sim.

    "before" reconstructs the PR-1 host loop cost from the tick count (it
    dispatched the edge sampler and the round separately for every tick);
    "after" is the measured ``GossipNetwork.device_calls`` of the batched
    driver running the same schedule.
    """
    dcfg = default_dagfl_config(num_nodes=num_nodes)
    sim = SimConfig(iterations=iterations, eval_every=25, seed=seed)
    task, nodes, gval, _ = make_cnn_setup(num_nodes=num_nodes, seed=seed)
    res = run_dagfl_gossip(
        task, nodes, dcfg, sim, gval,
        topology=topo.k_regular(num_nodes, 4, seed=seed),
        gossip=gossip_lib.GossipConfig(sync_period=1.0, seed=seed),
    )
    ticks = int(res.extras["sync_rounds"])
    calls = int(res.extras["device_calls"])
    sim_s = float(res.times[-1])
    before = 2.0 * ticks / sim_s
    after = calls / sim_s
    ratio = before / max(after, 1e-12)
    emit(
        "gossip/dispatch_batching", ratio,
        f"nodes={num_nodes};sync_ticks={ticks};device_calls={calls};"
        f"before_per_sim_s={before:.2f};after_per_sim_s={after:.2f}",
    )
    if record is not None:
        record["dispatch_batching"] = dict(
            nodes=num_nodes, iterations=iterations, sync_ticks=ticks,
            device_calls=calls, sim_seconds=sim_s,
            dispatches_per_sim_second_before=before,
            dispatches_per_sim_second_after=after,
            improvement=ratio,
        )
    return ratio


# ---------------------------------------------------------------------------
# Bank gossip: Table-I bandwidth sweep + infinite-capacity equivalence
# ---------------------------------------------------------------------------


def _results_bitwise_equal(a, b) -> bool:
    """End-to-end bitwise equality of two SimResults — accuracy curve,
    timing, and every field of the union ledger. THE equivalence rule the
    bank-gossip and event-engine CI tripwires share; change it here and
    both smoke checks change together."""
    return (
        np.array_equal(a.accs, b.accs)
        and np.array_equal(a.times, b.times)
        and all(
            np.array_equal(np.asarray(getattr(a.extras["dag"], f)),
                           np.asarray(getattr(b.extras["dag"], f)))
            for f in a.extras["dag"]._fields
        )
    )


def _run_banked(n, iterations, seed, impl, bandwidth, bank_cfg, obs=None,
                engine="ticks"):
    dcfg = default_dagfl_config(num_nodes=n)
    sim = SimConfig(iterations=iterations, eval_every=max(iterations // 4, 1),
                    seed=seed)
    task, nodes, gval, _ = make_cnn_setup(num_nodes=n, seed=seed)
    return run_dagfl_gossip(
        task, nodes, dcfg, sim, gval,
        topology=topo.ring(n, seed=seed, bandwidth=bandwidth),
        gossip=gossip_lib.GossipConfig(sync_period=1.0, seed=seed, impl=impl),
        bank_gossip=bank_cfg, obs=obs, engine=engine,
    )


def run_bank_gossip(
    n: int = 16, iterations: int = 40, seed: int = 0,
    impls=("fused", "scan"), record: dict = None,
):
    """Model-payload transport priced on Table-I link classes (16-node ring).

    Two claims, machine-checked into ``BENCH_gossip_sync.json``:

    * EQUIVALENCE (the CI tripwire): with unlimited per-link capacity, bank
      gossip is bitwise the PR-3 bankless run — identical accuracy curve
      and union ledger — for every round impl;
    * SWEEP: pricing the paper's phi = 7 MB model over the Table-I link
      classes, time-to-model-availability decouples from row visibility:
      the max chunk backlog (``bank_lag``) grows as links shrink from the
      Table-I 100 Mbps budget to an IoT-class 1 Mbps uplink, while the
      byte meter records what the run actually paid.

    Every row is read off the exported telemetry (``extras["obs"]`` — the
    per-round ``chunk_lag`` series and the ``final`` snapshot), not off
    ``GossipNetwork`` private state; the banked equivalence run executes
    WITH collectors on, so the tripwire simultaneously re-proves that obs
    never perturbs the trajectory.
    """
    rows = []
    for impl in impls:
        base = _run_banked(n, iterations, seed, impl, float("inf"), None)
        banked = _run_banked(
            n, iterations, seed, impl, float("inf"),
            BankGossipConfig(chunks_per_slot=4), obs=ObsConfig(),
        )
        equivalent = _results_bitwise_equal(base, banked)
        rep = banked.extras["obs"]
        emit(
            f"gossip/bank_gossip/equivalence/{impl}", float(equivalent),
            f"bitwise_equal_unbanked={equivalent};"
            f"bytes={rep.final['bytes_sent']:.0f}",
        )
        rows.append(dict(
            kind="equivalence", impl=impl, n=n, iterations=iterations,
            bitwise_equal_unbanked=bool(equivalent),
            bytes_sent=float(rep.final["bytes_sent"]),
        ))
    for cls, bits in topo.TABLE1_LINK_CLASSES.items():
        res = _run_banked(
            n, iterations, seed, "fused", bits,
            BankGossipConfig(chunks_per_slot=4, slot_bytes=7e6),   # Table-I phi
            obs=ObsConfig(),
        )
        rep = res.extras["obs"]
        lag_series = rep.series["chunk_lag"]
        peak_lag = int(lag_series.max()) if len(lag_series) else 0
        final_missing = int(rep.final["chunk_lag"])
        emit(
            f"gossip/bank_gossip/sweep/{cls}", peak_lag,
            f"final_acc={res.accs[-1]:.3f};final_missing={final_missing};"
            f"bytes={rep.final['bytes_sent']:.3g}",
        )
        rows.append(dict(
            kind="sweep", link_class=cls,
            bandwidth_bps=bits if np.isfinite(bits) else None, n=n,
            iterations=iterations, peak_chunk_lag=peak_lag,
            final_missing_chunks=final_missing,
            bytes_sent=float(rep.final["bytes_sent"]),
            final_acc=float(res.accs[-1]),
        ))
    if record is not None:
        record["bank_gossip"] = rows
    return rows


# ---------------------------------------------------------------------------
# Wire compression: identity-codec equivalence + accuracy-vs-bytes Pareto
# ---------------------------------------------------------------------------


def run_delta_codec(
    n: int = 16, iterations: int = 40, seed: int = 0,
    sweeps=(("lte_10mbps", 7e6), ("constrained_1mbps", 7e6),
            ("constrained_1mbps", 1.75e5)),
    codec_kinds=("int8", "int4", "topk"),
    ident_n: int = 8, ident_iterations: int = 10,
    record: dict = None,
):
    """Compressed-delta gossip (``repro.kernels.delta_codec``) measurements.

    Two claims, machine-checked into ``BENCH_gossip_sync.json`` under
    ``delta_codec``:

    * IDENTITY (the CI tripwire): an explicit ``DeltaCodec(kind="none")``
      is bitwise the ``codec=None`` bank path end to end — identical
      accuracy curve, timing, and union ledger — on BOTH engines and with
      the fault layer armed (active spoofers, digests verified): the
      identity codec keys the very same jitted programs the uncompressed
      path compiles, not equivalent ones;
    * PARETO: sweeping the quantization/sparsification codecs over
      (Table-I link class, payload size) points trades accuracy against
      bytes on the wire. ``byte_reduction`` is the measured byte-meter
      ratio of the compressed run to the uncompressed one — NOT the
      codec's nominal ``wire_ratio`` — so it only materializes when the
      compressed run can DRAIN its backlog and go idle while the raw run
      keeps paying. The grid spans both regimes honestly: at the paper's
      phi = 7 MB on the 1 Mbps class even 7.5x compression cannot keep up
      with one publish per second, both runs stay budget-limited, and the
      reduction collapses to ~1x (what compression buys there is a
      smaller chunk BACKLOG, the ``final_missing`` column); at a
      bench-scale 175 KB payload the compressed run syncs fully and the
      meter shows the near-nominal reduction — the acceptance row
      (int4: >= 4x fewer bytes, accuracy within 1% of the raw run).
      ``acc_drop`` is the accuracy the lossy format actually cost
      (negative = the codec run ended AHEAD because payloads arrived
      sooner).
    """
    rows = []

    def bank(codec=None, sb=7e6):
        return BankGossipConfig(chunks_per_slot=4, slot_bytes=sb, codec=codec)

    # identity: both engines over finite links so pricing is exercised
    for engine in ("ticks", "events"):
        base = _run_banked(ident_n, ident_iterations, seed, "fused", 10e6,
                           bank(), engine=engine)
        ident = _run_banked(ident_n, ident_iterations, seed, "fused", 10e6,
                            bank(DeltaCodec(kind="none")), engine=engine)
        equivalent = _results_bitwise_equal(base, ident)
        emit(
            f"gossip/delta_codec/identity/{engine}", float(equivalent),
            f"bitwise_equal_uncompressed={equivalent}",
        )
        rows.append(dict(
            kind="identity", engine=engine, faults=False, n=ident_n,
            iterations=ident_iterations,
            bitwise_equal_uncompressed=bool(equivalent),
        ))
    # identity with the fault layer armed: spoofers active, digests verified
    spoof = FaultConfig(
        roles=tuple(ROLE_SPOOF if i in (1, 2) else ROLE_HONEST
                    for i in range(ident_n)),
        spoof_rate=1.0, verify_digests=True, quarantine_after=3,
    )
    base = _run_faulted(ident_n, ident_iterations, seed, "ticks", spoof,
                        bank=bank())
    ident = _run_faulted(ident_n, ident_iterations, seed, "ticks", spoof,
                         bank=bank(DeltaCodec(kind="none")))
    equivalent = _results_bitwise_equal(base, ident)
    emit(
        "gossip/delta_codec/identity/faulted", float(equivalent),
        f"bitwise_equal_uncompressed={equivalent}",
    )
    rows.append(dict(
        kind="identity", engine="ticks", faults=True, n=ident_n,
        iterations=ident_iterations,
        bitwise_equal_uncompressed=bool(equivalent),
    ))

    # Pareto: codecs x (Table-I link class, payload size), measured off
    # the byte meter
    all_kinds = ("none",) + tuple(codec_kinds)
    for cls, sb in sweeps:
        bits = topo.TABLE1_LINK_CLASSES[cls]
        per = {}
        for kind in all_kinds:
            codec = None if kind == "none" else DeltaCodec(kind=kind)
            res = _run_banked(n, iterations, seed, "fused", bits,
                              bank(codec, sb), obs=ObsConfig())
            rep = res.extras["obs"]
            per[kind] = dict(
                bytes=float(rep.final["bytes_sent"]),
                acc=float(res.accs[-1]),
                missing=int(rep.final["chunk_lag"]),
                ratio=float(codec.wire_ratio()) if codec is not None else 1.0,
            )
        base_row = per["none"]
        for kind in all_kinds:
            d = per[kind]
            reduction = base_row["bytes"] / max(d["bytes"], 1e-9)
            acc_drop = base_row["acc"] - d["acc"]
            emit(
                f"gossip/delta_codec/pareto/{cls}/phi{sb:g}/{kind}",
                reduction,
                f"bytes={d['bytes']:.3g};final_acc={d['acc']:.3f};"
                f"acc_drop={acc_drop:+.4f};final_missing={d['missing']};"
                f"wire_ratio={d['ratio']:.4f}",
            )
            rows.append(dict(
                kind="pareto", link_class=cls, codec=kind,
                wire_ratio=d["ratio"], bytes_sent=d["bytes"],
                byte_reduction=float(reduction), final_acc=d["acc"],
                acc_drop=float(acc_drop), final_missing=d["missing"],
                n=n, iterations=iterations, slot_bytes=float(sb),
            ))
    if record is not None:
        record["delta_codec"] = rows
    return rows


# ---------------------------------------------------------------------------
# Event engine: tick-limit equivalence + the continuous-time payoff
# ---------------------------------------------------------------------------


def _run_engine(n, iterations, seed, impl, engine, link_latency):
    dcfg = default_dagfl_config(num_nodes=n)
    sim = SimConfig(iterations=iterations, eval_every=max(iterations // 4, 1),
                    seed=seed)
    task, nodes, gval, _ = make_cnn_setup(num_nodes=n, seed=seed)
    return run_dagfl_gossip(
        task, nodes, dcfg, sim, gval,
        topology=topo.ring(n, link_latency=link_latency, seed=seed),
        gossip=gossip_lib.GossipConfig(sync_period=1.0, seed=seed, impl=impl),
        engine=engine,
    )


def run_event_engine(
    n: int = 8, iterations: int = 12, seed: int = 0,
    impls=("fused", "scan"), insystem_horizon: float = 2000.0,
    record: dict = None,
):
    """Continuous-time event engine (``repro.net.events``) measurements.

    Three claims, machine-checked into ``BENCH_gossip_sync.json``:

    * EQUIVALENCE (the CI tripwire): with a uniform deterministic per-edge
      delay equal to the sync period, ``engine="events"`` is bitwise the
      ``engine="ticks"`` fused path end to end — identical accuracy curve,
      timing, and union ledger — for every round impl;
    * PROPAGATION: on an overlay whose links are FASTER than the tick
      (latency 0.3 s, period 1 s), the event engine syncs a published row
      in per-hop latency time while the stride model waits for whole ticks
      — the measured full-sync times are reported side by side;
    * IN-SYSTEM Eq. (4): the §IV tip equilibrium measured inside the full
      gossip system lands near ``stability.equilibrium_tips`` (the full
      bench-grid comparison is ``benchmarks/stability_tips.py``; this row
      is the compact JSON copy).
    """
    from repro.core import stability
    from repro.net.events import simulate_insystem_tips

    rows = []
    for impl in impls:
        base = _run_engine(n, iterations, seed, impl, "ticks", 1.0)
        ev = _run_engine(n, iterations, seed, impl, "events", 1.0)
        equivalent = _results_bitwise_equal(base, ev)
        emit(
            f"gossip/event_engine/equivalence/{impl}", float(equivalent),
            f"bitwise_equal_ticks={equivalent};"
            f"event_batches={ev.extras['events_processed']}",
        )
        rows.append(dict(
            kind="equivalence", impl=impl, n=n, iterations=iterations,
            bitwise_equal_ticks=bool(equivalent),
            event_batches=int(ev.extras["events_processed"]),
        ))

    # propagation: one row crossing a 12-node ring of 0.3 s links
    def _sync_time(engine):
        m = 12
        d = dag_lib.empty_dag(32, 2, m + 1)
        d = dag_lib.publish(
            d, jnp.asarray(m, jnp.int32), jnp.float32(0.0),
            jnp.full((2,), dag_lib.NO_TX, jnp.int32), jnp.float32(0.5),
            jnp.float32(0.0), jnp.asarray(0, jnp.int32),
        )
        net = gossip_lib.GossipNetwork(
            d, bank=jnp.zeros((32, 4)),
            top=topo.ring(m, link_latency=0.3, seed=seed),
            cfg=gossip_lib.GossipConfig(sync_period=1.0, seed=seed,
                                        engine=engine),
        )
        di = replica_lib.publish_local(
            net.read(0), 1, jnp.asarray(0, jnp.int32), jnp.float32(0.05),
            jnp.full((2,), dag_lib.NO_TX, jnp.int32), jnp.float32(0.5),
            jnp.float32(0.0), jnp.asarray(1, jnp.int32),
        )
        net.write(0, di)
        t = 0.0
        while not net.synced() and t < 30.0:
            t = round(t + 0.1, 10)
            net.advance(t)
        if not net.synced():      # never report the timeout as a sync time
            raise RuntimeError(f"engine={engine} failed to sync within 30 s")
        return t

    t_ticks, t_events = _sync_time("ticks"), _sync_time("events")
    emit(
        "gossip/event_engine/full_sync_time", t_events,
        f"events_s={t_events:.1f};ticks_s={t_ticks:.1f};"
        f"speedup={t_ticks / max(t_events, 1e-9):.2f}",
    )
    rows.append(dict(
        kind="propagation", link_latency_s=0.3, sync_period_s=1.0,
        full_sync_s_events=t_events, full_sync_s_ticks=t_ticks,
    ))

    if insystem_horizon > 0:
        # bench-grid parameters (benchmarks/stability_tips.py): horizons
        # shorter than ~2000 leave too much tail noise for the 15% band
        cfg = default_dagfl_config(num_nodes=16)
        f = 1.5e9
        pred = stability.equilibrium_tips(cfg, f)
        tr = simulate_insystem_tips(
            topo.full(16), h=stability.iteration_delay(cfg, f),
            arrival_rate=cfg.arrival_rate, k=cfg.k, tau_max=cfg.tau_max,
            horizon=insystem_horizon, capacity=256, seed=seed,
            sync_period=0.05,
        )
        ins = tr.tail_mean(0.5)
        rel = abs(ins - pred) / pred
        emit(
            "gossip/event_engine/insystem_eq4", ins,
            f"L0_pred={pred:.2f};rel_err={rel:.3f};published={tr.published}",
        )
        rows.append(dict(
            kind="insystem_eq4", k=cfg.k, horizon=insystem_horizon,
            L0_pred=float(pred), L0_insystem=float(ins),
            rel_err=float(rel), published=int(tr.published),
            overflow=int(tr.overflow),
        ))
    if record is not None:
        record["event_engine"] = rows
    return rows


# ---------------------------------------------------------------------------
# Observability: zero-perturbation equivalence + collector overhead
# ---------------------------------------------------------------------------


def _run_observed(n, iterations, seed, engine, obs):
    dcfg = default_dagfl_config(num_nodes=n)
    sim = SimConfig(iterations=iterations, eval_every=max(iterations // 4, 1),
                    seed=seed)
    task, nodes, gval, _ = make_cnn_setup(num_nodes=n, seed=seed)
    with timed() as t:
        res = run_dagfl_gossip(
            task, nodes, dcfg, sim, gval,
            topology=topo.ring(n, link_latency=1.0, seed=seed),
            gossip=gossip_lib.GossipConfig(sync_period=1.0, seed=seed),
            engine=engine, obs=obs,
        )
    return res, t["s"]


def run_observability(
    n: int = 8, iterations: int = 12, seed: int = 0,
    engines=("ticks", "events"), trace_path: str = TRACE_SAMPLE_PATH,
    record: dict = None,
):
    """In-loop telemetry (``repro.obs``) measurements.

    Two claims per engine, machine-checked into ``BENCH_gossip_sync.json``:

    * EQUIVALENCE (the CI tripwire): an obs-instrumented run — metric
      accumulators and the trace ring threaded through every jitted loop —
      is bitwise the obs-off run end to end (accuracy curve, timing, union
      ledger): collection is a pure read;
    * OVERHEAD: the warmed wall-time cost of collecting. Each arm runs
      twice (first run pays compilation, second is timed; best-of rule:
      the min) and the obs-on/obs-off ratio must stay under 1.10 — the
      <10% acceptance bound.

    A third "hist" arm (``ObsConfig(hist=HistConfig())``) re-checks both
    claims with the streaming latency histograms threaded through the
    loop and records the publish->commit propagation-delay distribution
    (the paper's SS-IV confirmation-delay curve) — bin counts plus the
    p50/p95/p99 summaries — as a ``kind="hist"`` row per engine.

    Side effect: the last hist-on report is exported to ``trace_path``
    as a Chrome/Perfetto trace (iteration spans + ``hist:`` counter
    tracks) — the artifact CI uploads.
    """
    rows = []
    report = None
    arms = (("off", None), ("on", ObsConfig()),
            ("hist", ObsConfig(hist=HistConfig())))
    for engine in engines:
        walls = {}
        results = {}
        for tag, obs in arms:
            best = float("inf")
            for _ in range(2):                     # warmup, then timed
                res, wall = _run_observed(n, iterations, seed, engine, obs)
                best = min(best, wall)
            walls[tag], results[tag] = best, res
        equivalent = _results_bitwise_equal(results["off"], results["on"])
        overhead = walls["on"] / max(walls["off"], 1e-12)
        report = results["on"].extras["obs"]
        emit(
            f"gossip/observability/{engine}", overhead,
            f"bitwise_equal_obs_off={equivalent};"
            f"overhead_ratio={overhead:.3f};rounds={report.rounds};"
            f"samples={len(report.series['t'])};"
            f"trace_events={len(report.trace['t'])};"
            f"trace_dropped={report.trace_dropped}",
        )
        rows.append(dict(
            kind="equivalence", engine=engine, n=n, iterations=iterations,
            bitwise_equal_obs_off=bool(equivalent),
            overhead_ratio=float(overhead),
            wall_s_obs_off=float(walls["off"]), wall_s_obs_on=float(walls["on"]),
            rounds=int(report.rounds), samples=int(len(report.series["t"])),
            trace_events=int(len(report.trace["t"])),
            trace_dropped=int(report.trace_dropped),
            dispatch_counts=dict(report.dispatch_counts),
        ))
        hist_equal = _results_bitwise_equal(results["off"], results["hist"])
        hist_overhead = walls["hist"] / max(walls["off"], 1e-12)
        report = results["hist"].extras["obs"]
        hist = report.hist
        commit_pct = hist["percentiles"]["commit_lat"]
        emit(
            f"gossip/observability/hist/{engine}", hist_overhead,
            f"bitwise_equal_obs_off={hist_equal};"
            f"overhead_ratio={hist_overhead:.3f};"
            f"commit_lat_samples={commit_pct['samples']};"
            f"commit_lat_p50={commit_pct['p50']:.3f};"
            f"commit_lat_p99={commit_pct['p99']:.3f}",
        )
        rows.append(dict(
            kind="hist", engine=engine, n=n, iterations=iterations,
            bitwise_equal_obs_off=bool(hist_equal),
            overhead_ratio=float(hist_overhead),
            wall_s_obs_off=float(walls["off"]),
            wall_s_hist_on=float(walls["hist"]),
            bins=int(hist["bins"]), lo=float(hist["lo"]), hi=float(hist["hi"]),
            commit_lat_counts=[int(x) for x in hist["counts"]["commit_lat"]],
            merge_lat_counts=[int(x) for x in hist["counts"]["merge_lat"]],
            percentiles={
                name: {k: (None if isinstance(v, float)
                           and not np.isfinite(v) else v)
                       for k, v in summ.items()}
                for name, summ in hist["percentiles"].items()
            },
        ))
    if report is not None and trace_path:
        os.makedirs(os.path.dirname(trace_path) or ".", exist_ok=True)
        write_chrome_trace(report, trace_path)
        print(f"# wrote {trace_path}")
    if record is not None:
        record["observability"] = rows
    return rows


# ---------------------------------------------------------------------------
# Fault injection: faults-off equivalence + the spoof-defense tripwire
# ---------------------------------------------------------------------------


def _run_faulted(n, iterations, seed, engine, faults, bank=None):
    dcfg = default_dagfl_config(num_nodes=n)
    sim = SimConfig(iterations=iterations, eval_every=max(iterations // 4, 1),
                    seed=seed)
    task, nodes, gval, _ = make_cnn_setup(num_nodes=n, seed=seed)
    return run_dagfl_gossip(
        task, nodes, dcfg, sim, gval,
        topology=topo.full(n, link_latency=1.0, seed=seed),
        gossip=gossip_lib.GossipConfig(sync_period=1.0, seed=seed),
        engine=engine, bank_gossip=bank, faults=faults,
    )


def run_fault_suite(
    n: int = 8, iterations: int = 10, seed: int = 0,
    engines=("ticks", "events"), record: dict = None,
):
    """Adversarial fault layer (``repro.net.faults``) measurements.

    Two claims per engine, machine-checked into ``BENCH_gossip_sync.json``
    under ``attack_suite``:

    * EQUIVALENCE (the CI tripwire): an all-HONEST ``FaultConfig`` — the
      fault layer armed but every node behaving — is bitwise the
      ``faults=None`` run end to end (accuracy curve, timing, union
      ledger): the role draws live on a salted side stream and the
      injection points compile away;
    * SPOOF DEFENSE (the CI tripwire): under active payload spoofers with
      digest verification on, the transport-level attack-success rate —
      corrupted chunks visible through any gated view — is ZERO while
      rejections accrue and the spoofers' links are quarantined.
    """
    rows = []
    spoof_roles = tuple(
        ROLE_SPOOF if i in (1, 2, 3) else ROLE_HONEST for i in range(n)
    )
    for engine in engines:
        base = _run_faulted(n, iterations, seed, engine, None)
        hon = _run_faulted(
            n, iterations, seed, engine, FaultConfig(roles=(ROLE_HONEST,) * n)
        )
        equivalent = _results_bitwise_equal(base, hon)
        emit(
            f"gossip/fault_suite/equivalence/{engine}", float(equivalent),
            f"bitwise_equal_unfaulted={equivalent}",
        )
        rows.append(dict(
            kind="equivalence", engine=engine, n=n, iterations=iterations,
            bitwise_equal_unfaulted=bool(equivalent),
        ))
        adv = _run_faulted(
            n, iterations, seed, engine,
            FaultConfig(roles=spoof_roles, spoof_rate=1.0,
                        verify_digests=True, quarantine_after=3),
            bank=BankGossipConfig(chunks_per_slot=4),
        )
        rep = adv.extras["fault_report"]
        asr = int(np.asarray(rep["tainted_in_views"]).sum())
        emit(
            f"gossip/fault_suite/spoof_defense/{engine}", float(asr),
            f"attack_success={asr};rejected={rep['rejected_total']};"
            f"quarantined={rep['quarantined_links']};"
            f"final_acc={adv.accs[-1]:.3f}",
        )
        rows.append(dict(
            kind="spoof_defense", engine=engine, n=n, iterations=iterations,
            spoofers=sum(r == ROLE_SPOOF for r in spoof_roles),
            attack_success=asr, rejected=int(rep["rejected_total"]),
            quarantined_links=int(rep["quarantined_links"]),
            final_acc=float(adv.accs[-1]),
        ))
    if record is not None:
        record["attack_suite"] = rows
    return rows


def write_bench_json(record: dict, path: str = JSON_PATH) -> None:
    record = dict(record, schema="gossip_sync_bench_v1", backend=jax.default_backend())
    with open(path, "w") as f:
        json.dump(record, f, indent=2, sort_keys=True)
    print(f"# wrote {path}")


def run_sync_bench(json_path: str = JSON_PATH, record: dict = None):
    """Everything BENCH_gossip_sync.json carries: the fast-path grid, the
    sharded round, dispatch batching, the bank-gossip equivalence +
    bandwidth sweep, the event-engine equivalence + continuous-time rows,
    the observability equivalence + overhead rows, the attack-suite
    equivalence + spoof-defense rows, and the serving-load zero-rate
    equivalence + Table-I throughput/staleness rows (no accuracy
    sweeps)."""
    from benchmarks import serve_load as serve_load_bench

    own = record is None
    record = {} if own else record
    run_sync_round_grid(record=record)
    run_sharded_sync(record=record)
    run_dispatch_batching(record=record)
    run_bank_gossip(record=record)
    run_delta_codec(record=record)
    run_event_engine(record=record)
    run_observability(record=record)
    run_fault_suite(record=record)
    serve_load_bench.run_serve_load(record=record)
    if own:
        write_bench_json(record, json_path)
    return record


# ---------------------------------------------------------------------------
# Accuracy sweeps (unchanged claims)
# ---------------------------------------------------------------------------


def run_sweep(iterations: int = 150, num_nodes: int = 25, seed: int = 0):
    """Accuracy vs time across sync periods and drop rates on a k-regular
    overlay, against the shared-ledger baseline."""
    dcfg = default_dagfl_config(num_nodes=num_nodes)
    sim = SimConfig(iterations=iterations, eval_every=25, seed=seed)

    task, nodes, gval, _ = make_cnn_setup(num_nodes=num_nodes, seed=seed)
    with timed() as t:
        base = run_dagfl(task, nodes, dcfg, sim, gval)
    _emit_result("gossip/baseline_shared_ledger", base, t["s"], iterations)

    for period in (0.0, 1.0, 4.0, 16.0):
        for drop in (0.0, 0.3):
            if period == 0.0 and drop > 0:
                continue                    # ideal wire is loss-free by definition
            task, nodes, gval, _ = make_cnn_setup(num_nodes=num_nodes, seed=seed)
            top = topo.k_regular(num_nodes, 4, drop=drop, seed=seed)
            with timed() as t:
                res = run_dagfl_gossip(
                    task, nodes, dcfg, sim, gval, topology=top,
                    gossip=gossip_lib.GossipConfig(sync_period=period, seed=seed),
                )
            _emit_result(
                f"gossip/period_{period:g}/drop_{drop:g}", res, t["s"], iterations
            )
    return base


def run_partition(iterations: int = 150, num_nodes: int = 25, seed: int = 0):
    """Split the overlay down the middle for the middle third of the run."""
    dcfg = default_dagfl_config(num_nodes=num_nodes)
    sim = SimConfig(iterations=iterations, eval_every=25, seed=seed)
    # Poisson arrivals at rate 1/s: t ~ iteration index
    part = gossip_lib.PartitionSchedule(
        assignment=topo.split_halves(num_nodes),
        t_start=iterations / 3.0,
        t_end=2.0 * iterations / 3.0,
    )
    task, nodes, gval, _ = make_cnn_setup(num_nodes=num_nodes, seed=seed)
    with timed() as t:
        res = run_dagfl_gossip(
            task, nodes, dcfg, sim, gval,
            topology=topo.k_regular(num_nodes, 4, seed=seed),
            gossip=gossip_lib.GossipConfig(sync_period=1.0, seed=seed),
            partition=part,
        )
    _emit_result("gossip/partition_heal", res, t["s"], iterations)
    div = res.extras["divergence_curve"]
    if len(div):
        peak = int(div[:, 2].max())
        emit("gossip/partition_peak_divergence", peak, f"rows={peak}")
    return res


def run(iterations: int = 150, num_nodes: int = 25, seed: int = 0,
        json_path: str = JSON_PATH):
    record = {}
    run_sync_round_grid(record=record)
    run_dispatch_batching(iterations=iterations, num_nodes=num_nodes, seed=seed,
                          record=record)
    run_bank_gossip(seed=seed, record=record)
    run_delta_codec(seed=seed, record=record)
    run_event_engine(seed=seed, record=record)
    run_observability(seed=seed, record=record)
    write_bench_json(record, json_path)
    run_sweep(iterations=iterations, num_nodes=num_nodes, seed=seed)
    run_partition(iterations=iterations, num_nodes=num_nodes, seed=seed)


def smoke(json_path: str = JSON_PATH) -> int:
    """CI tripwire: reduced grid; fail on lost scan/fused equivalence, a
    < 2x speedup, a mesh-sharded round that diverges from the single-device
    fused round (when >1 device is visible — the 8-device CI lane), a
    bank-gossip run at unlimited capacity that is no longer bitwise the
    bankless PR-3 path, an event-engine run in the degenerate
    uniform-delay limit that is no longer bitwise the tick path, an
    obs-instrumented run that is no longer bitwise the obs-off path, a
    warmed obs-on run costing more than 10% extra wall time, an
    all-honest fault config that is no longer bitwise the un-faulted
    path, a spoofed chunk that survives digest verification into a
    gated view (attack_success != 0 / zero rejections), an identity
    delta codec (``DeltaCodec(kind="none")``) that is no longer bitwise
    the ``codec=None`` bank path (engines x faults), a compressed
    codec whose measured byte reduction drops below 2x on the
    constrained 1 Mbps class, a zero-rate serving config that is no
    longer bitwise the serve-free path, an ideal-wire serving arm
    that serves zero requests, a histogram-instrumented run that is no
    longer bitwise the obs-off path (or costs >10% wall time, or samples
    no merge latencies), or a serving arm whose per-request percentile
    ladder comes back degenerate (zero queue-wait samples).

    N=48 so the same grid point serves the sharded check (48 tiles over
    both the 8x1 and 2x4 meshes the acceptance pins).
    """
    record = {"mode": "smoke"}
    rows = run_sync_round_grid(
        ns=(48,), caps=(128,), reps=10, record=record,
    )
    sharded_rows = run_sharded_sync(reps=5, record=record)
    bank_rows = run_bank_gossip(n=8, iterations=10, record=record)
    codec_rows = run_delta_codec(
        n=8, iterations=10, sweeps=(("constrained_1mbps", 7e5),),
        codec_kinds=("int4",), ident_n=6, ident_iterations=8,
        record=record,
    )
    event_rows = run_event_engine(
        n=6, iterations=8, impls=("fused",), insystem_horizon=0.0,
        record=record,
    )
    obs_rows = run_observability(n=6, iterations=10, record=record)
    fault_rows = run_fault_suite(
        n=6, iterations=8, engines=("ticks",), record=record,
    )
    from benchmarks import serve_load as serve_load_bench
    serve_rows = serve_load_bench.run_serve_load(
        n=6, iterations=8, link_classes=("ideal", "lte_10mbps"),
        record=record,
    )
    write_bench_json(record, json_path)
    ok = True
    for row in rows:
        if "bitwise_equivalent" in row and not row["bitwise_equivalent"]:
            print(f"# SMOKE FAIL: fused round diverged from scan at {row}")
            ok = False
        if row.get("speedup_vs_scan", float("inf")) < 2.0:
            print(f"# SMOKE FAIL: fused speedup below 2x: {row}")
            ok = False
    for row in sharded_rows:
        if not row["bitwise_equal_fused"]:
            print(f"# SMOKE FAIL: mesh-sharded round diverged from fused: {row}")
            ok = False
    if jax.device_count() > 1 and not sharded_rows:
        print("# SMOKE FAIL: multi-device backend but no sharded rows recorded")
        ok = False
    for row in bank_rows:
        if row["kind"] == "equivalence" and not row["bitwise_equal_unbanked"]:
            print(f"# SMOKE FAIL: bank gossip at unlimited capacity diverged "
                  f"from the bankless path: {row}")
            ok = False
    if not any(r["kind"] == "equivalence" for r in bank_rows):
        print("# SMOKE FAIL: no bank-gossip equivalence rows recorded")
        ok = False
    for row in codec_rows:
        if row["kind"] == "identity" and not row["bitwise_equal_uncompressed"]:
            print(f"# SMOKE FAIL: identity codec diverged from the "
                  f"uncompressed bank path: {row}")
            ok = False
        if (row["kind"] == "pareto" and row["codec"] != "none"
                and row["link_class"] == "constrained_1mbps"
                and row["byte_reduction"] < 2.0):
            print(f"# SMOKE FAIL: codec byte reduction below 2x on the "
                  f"constrained link class: {row}")
            ok = False
    if not any(r["kind"] == "identity" for r in codec_rows):
        print("# SMOKE FAIL: no identity-codec rows recorded")
        ok = False
    if not any(r["kind"] == "pareto" and r["codec"] != "none"
               for r in codec_rows):
        print("# SMOKE FAIL: no compressed pareto rows recorded")
        ok = False
    for row in event_rows:
        if row["kind"] == "equivalence" and not row["bitwise_equal_ticks"]:
            print(f"# SMOKE FAIL: event engine in the uniform-delay limit "
                  f"diverged from the tick path: {row}")
            ok = False
    if not any(r["kind"] == "equivalence" for r in event_rows):
        print("# SMOKE FAIL: no event-engine equivalence rows recorded")
        ok = False
    for row in obs_rows:
        if not row["bitwise_equal_obs_off"]:
            print(f"# SMOKE FAIL: obs-instrumented run diverged from the "
                  f"obs-off path: {row}")
            ok = False
        if row["overhead_ratio"] > 1.10:
            print(f"# SMOKE FAIL: obs collector overhead above 10%: {row}")
            ok = False
        if row["kind"] == "hist" and sum(row["merge_lat_counts"]) == 0:
            print(f"# SMOKE FAIL: hist arm sampled no merge latencies — "
                  f"the streaming histograms never fired: {row}")
            ok = False
    if not obs_rows:
        print("# SMOKE FAIL: no observability rows recorded")
        ok = False
    if not any(r["kind"] == "hist" for r in obs_rows):
        print("# SMOKE FAIL: no histogram rows recorded")
        ok = False
    for row in fault_rows:
        if row["kind"] == "equivalence" and not row["bitwise_equal_unfaulted"]:
            print(f"# SMOKE FAIL: all-honest fault config diverged from the "
                  f"un-faulted path: {row}")
            ok = False
        if row["kind"] == "spoof_defense":
            if row["attack_success"] != 0:
                print(f"# SMOKE FAIL: spoofed chunk survived digest "
                      f"verification into a gated view: {row}")
                ok = False
            if row["rejected"] == 0:
                print(f"# SMOKE FAIL: spoof run recorded no rejections — "
                      f"the defense never engaged: {row}")
                ok = False
    if not any(r["kind"] == "spoof_defense" for r in fault_rows):
        print("# SMOKE FAIL: no spoof-defense rows recorded")
        ok = False
    for row in serve_rows:
        if row["kind"] == "zero_rate" and not row["bitwise_equal_unserved"]:
            print(f"# SMOKE FAIL: zero-rate serving diverged from the "
                  f"serve-free path: {row}")
            ok = False
        if (row["kind"] == "load" and row["link_class"] == "ideal"
                and row["served_total"] == 0):
            print(f"# SMOKE FAIL: ideal-wire serving arm served zero "
                  f"requests — the Poisson load never fired: {row}")
            ok = False
        if (row["kind"] == "load"
                and not row.get("arrivals_match_replay", True)):
            print(f"# SMOKE FAIL: engine arrivals diverged from the host "
                  f"Poisson replay — events were truncated or the serve "
                  f"key branch drifted: {row}")
            ok = False
        if row["kind"] == "load" and row["served_total"] > 0:
            ladder = row.get("request_percentiles")
            if not ladder or ladder["queue_wait"]["samples"] == 0:
                print(f"# SMOKE FAIL: serving arm returned a degenerate "
                      f"per-request percentile ladder (no queue-wait "
                      f"samples despite served requests): {row}")
                ok = False
    if not any(r["kind"] == "zero_rate" for r in serve_rows):
        print("# SMOKE FAIL: no zero-rate serve rows recorded")
        ok = False
    if not any(r["kind"] == "load" and r["link_class"] == "ideal"
               for r in serve_rows):
        print("# SMOKE FAIL: no ideal-wire serve rows recorded")
        ok = False
    print(f"# smoke {'ok' if ok else 'FAILED'}")
    return 0 if ok else 1


if __name__ == "__main__":
    from benchmarks.common import header

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced grid + equivalence/speedup tripwire")
    ap.add_argument("--json", default=JSON_PATH)
    args = ap.parse_args()
    header()
    if args.smoke:
        sys.exit(smoke(json_path=args.json))
    run(json_path=args.json)
