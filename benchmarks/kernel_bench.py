"""Kernel micro-bench: Pallas (interpret on CPU) + jnp reference timings.

On this CPU container the absolute numbers are NOT TPU times; the table
establishes correctness-at-scale and the block-shape sweep used to pick
BlockSpecs (EXPERIMENTS.md §Perf discusses the VMEM reasoning).
"""
import time

import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.kernels import ops, ref


def _time(fn, *args, reps=3):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps * 1e6


def run():
    key = jax.random.PRNGKey(0)
    # fedavg: k x N streaming reduction (Eq. 1 hot spot)
    for k, n in ((2, 1 << 20), (8, 1 << 20)):
        w = jax.nn.softmax(jax.random.normal(key, (k,)))
        m = jax.random.normal(key, (k, n), jnp.float32)
        us_ref = _time(lambda: ref.fedavg_ref(w, m))
        us_pal = _time(lambda: ops.fedavg(w, m))
        emit(f"kernel/fedavg/k{k}_n{n}", us_pal, f"jnp_ref_us={us_ref:.0f}")

    # model distance
    m = jax.random.normal(key, (6, 1 << 19), jnp.float32)
    emit("kernel/model_distance/k6", _time(lambda: ops.model_distance(m)),
         f"jnp_ref_us={_time(lambda: ref.model_distance_ref(m)):.0f}")

    # flash attention (small shapes; interpret mode is slow by design)
    B, H, KV, S, hd = 1, 4, 2, 256, 64
    q = jax.random.normal(key, (B, H, S, hd)) * 0.3
    kk = jax.random.normal(key, (B, KV, S, hd)) * 0.3
    vv = jax.random.normal(key, (B, KV, S, hd))
    emit("kernel/flash_attention/s256", _time(lambda: ops.flash_attention(q, kk, vv)),
         f"jnp_ref_us={_time(lambda: ref.mqa_attention_ref(q, kk, vv)):.0f}")
