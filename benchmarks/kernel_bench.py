"""Kernel micro-bench: Pallas (interpret on CPU) + jnp reference timings.

On this CPU container the absolute numbers are NOT TPU times; the table
establishes correctness-at-scale and the block-shape sweep used to pick
BlockSpecs (EXPERIMENTS.md §Perf discusses the VMEM reasoning).
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.kernels import ops, ref
from repro.kernels.gossip_merge import gossip_winner_nbr


def _time(fn, *args, reps=3):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps * 1e6


def run():
    key = jax.random.PRNGKey(0)
    # fedavg: k x N streaming reduction (Eq. 1 hot spot)
    for k, n in ((2, 1 << 20), (8, 1 << 20)):
        w = jax.nn.softmax(jax.random.normal(key, (k,)))
        m = jax.random.normal(key, (k, n), jnp.float32)
        us_ref = _time(lambda: ref.fedavg_ref(w, m))
        us_pal = _time(lambda: ops.fedavg(w, m))
        emit(f"kernel/fedavg/k{k}_n{n}", us_pal, f"jnp_ref_us={us_ref:.0f}")

    # model distance
    m = jax.random.normal(key, (6, 1 << 19), jnp.float32)
    emit("kernel/model_distance/k6", _time(lambda: ops.model_distance(m)),
         f"jnp_ref_us={_time(lambda: ref.model_distance_ref(m)):.0f}")

    # flash attention (small shapes; interpret mode is slow by design)
    B, H, KV, S, hd = 1, 4, 2, 256, 64
    q = jax.random.normal(key, (B, H, S, hd)) * 0.3
    kk = jax.random.normal(key, (B, KV, S, hd)) * 0.3
    vv = jax.random.normal(key, (B, KV, S, hd))
    emit("kernel/flash_attention/s256", _time(lambda: ops.flash_attention(q, kk, vv)),
         f"jnp_ref_us={_time(lambda: ref.mqa_attention_ref(q, kk, vv)):.0f}")

    # delta codec: blocked quantization + top-k sparsification (the wire
    # compression path, repro.kernels.delta_codec; codes are exact vs the
    # oracle, scales agree to float rounding)
    x = jax.random.normal(key, (1024, 128), jnp.float32) * 0.02
    for name, qmax in (("int8", 127), ("int4", 7)):
        us_ref = _time(lambda: ref.quant_blocks_ref(x, qmax))
        us_pal = _time(lambda: ops.quant_blocks(x, qmax, impl="pallas"))
        emit(f"kernel/quant_blocks/{name}_nb1024_b128", us_pal,
             f"jnp_ref_us={us_ref:.0f}")
    d = jax.random.normal(key, (256, 128), jnp.float32) * 0.02
    us_ref = _time(lambda: ref.topk_blocks_ref(d, 8))
    us_pal = _time(lambda: ops.topk_blocks(d, 8, impl="pallas"))
    emit("kernel/topk_blocks/k8_nb256_b128", us_pal,
         f"jnp_ref_us={us_ref:.0f}")

    # gossip-merge winner selection (the anti-entropy sync hot spot): the
    # dense Pallas kernel and the degree-compressed lax path vs the dense
    # pure-lax oracle, on a k=4 overlay at R=64, cap=256
    rng = np.random.default_rng(0)
    R, C, D = 64, 256, 5
    pub = jnp.asarray(rng.integers(-1, R, (R, C)), jnp.int32)
    t = jnp.asarray(np.where(np.asarray(pub) >= 0, rng.random((R, C)), 0.0), jnp.float32)
    ac = jnp.asarray(rng.integers(0, 4, (R, C)), jnp.int32)
    mask = np.zeros((R, R), bool)
    for off in (1, 2):
        idx = np.arange(R)
        mask[idx, (idx + off) % R] = mask[idx, (idx - off) % R] = True
    np.fill_diagonal(mask, True)
    mask_j = jnp.asarray(mask)
    nbr_idx = jnp.asarray(
        np.argsort(~mask, axis=1, kind="stable")[:, :D].astype(np.int32)
    )
    nbr_act = jnp.take_along_axis(mask_j, nbr_idx, axis=1)
    nbr = jax.jit(gossip_winner_nbr)
    us_ref = _time(lambda: ref.gossip_winner_ref(t, pub, ac, mask_j))
    us_nbr = _time(lambda: nbr(t, pub, ac, nbr_idx, nbr_act))
    emit("kernel/gossip_winner/r64_c256",
         _time(lambda: ops.gossip_winner(t, pub, ac, mask_j, impl="pallas")),
         f"jnp_ref_us={us_ref:.0f};nbr_lax_us={us_nbr:.0f}")

    # histogram bincount (the streaming-telemetry scatter-add,
    # repro.kernels.hist_bincount): blocked one-hot accumulate vs the
    # at[].add oracle at the obs hot-spot shape (one advance's worth of
    # weighted latency samples into a 65-bin log-spaced layout)
    for m in (1 << 12, 1 << 16):
        idx = jnp.asarray(rng.integers(0, 65, (m,)), jnp.int32)
        w = jnp.asarray(rng.integers(0, 4, (m,)), jnp.int32)
        us_ref = _time(lambda: ref.hist_bincount_ref(idx, w, 65))
        us_pal = _time(lambda: ops.hist_bincount(idx, w, 65, impl="pallas"))
        emit(f"kernel/hist_bincount/m{m}_b65", us_pal,
             f"jnp_ref_us={us_ref:.0f}")
