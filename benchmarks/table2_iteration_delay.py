"""Paper Table II: average iteration latency / wall-clock per 100 iterations.

Paper values (CNN task): Google 150.04 s, Async 105.88 s, Block 113.91 s,
DAG-FL 107.43 s per 100 iterations — DAG-FL ~ Async < Block < Google.
We report both the per-iteration latency and the wall-clock of 100 iterations
from the Table-I latency model + Poisson arrivals.
"""
from benchmarks.common import emit, timed
from repro.fl.experiments import iteration_delay_experiment


def run(task_name: str = "cnn", iterations: int = 100, seed: int = 0):
    with timed() as t:
        out = iteration_delay_experiment(task_name, iterations, seed)
    for sysname in ("dagfl", "async", "block", "google"):
        lat = out[f"{sysname}_avg_iter_latency_s"]
        wall = out[f"{sysname}_wallclock_100_iters_s"] * (100.0 / iterations)
        emit(
            f"table2/{task_name}/{sysname}",
            lat * 1e6,
            f"wallclock_100_iters_s={wall:.1f}",
        )
    emit(f"table2/{task_name}/bench_runtime", t["s"] * 1e6, "")
    return out
