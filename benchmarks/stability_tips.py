"""§IV Eq. (4): equilibrium tip count — closed form vs Poisson simulation."""
from benchmarks.common import emit, timed
from repro.configs.base import DagFLConfig
from repro.core import stability


def run(seed: int = 0):
    rows = {}
    for k, alpha in ((2, 5), (3, 5), (4, 6)):
        cfg = DagFLConfig(num_nodes=100, alpha=alpha, k=k)
        f = 1.5e9
        pred = stability.equilibrium_tips(cfg, f)
        with timed() as t:
            trace = stability.simulate_tip_count(cfg, horizon=2000.0, seed=seed, f=f)
        sim = trace.tail_mean(0.5)
        rows[k] = (pred, sim)
        emit(
            f"stability/eq4/k{k}_alpha{alpha}",
            t["s"] * 1e6,
            f"L0_pred={pred:.2f};L0_sim={sim:.2f};rel_err={abs(sim-pred)/pred:.3f}",
        )
    return rows
