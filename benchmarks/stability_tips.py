"""§IV Eq. (4): equilibrium tip count — closed form vs simulation, two ways.

Three measurements per (k, alpha) grid point:

* ``stability/eq4/...``           the closed form L0 = k*lambda*h/(k-1)
                                  against the standalone numpy Poisson
                                  simulation (``core.stability`` — one
                                  global tip set, no network);
* ``stability/eq4_insystem/...``  the SAME process measured INSIDE the full
                                  gossip system (``repro.net.events.
                                  simulate_insystem_tips``): per-node DAG
                                  replicas over a continuous-time overlay,
                                  tips counted on the union view. With a
                                  well-connected overlay and delivery
                                  intervals well under h the tail mean
                                  lands within 15% of the closed form (the
                                  acceptance band; the residual above the
                                  standalone sim is real gossip staleness —
                                  replicas approve from views a delivery
                                  interval old).

``--quick`` shortens the in-system horizon for a fast sanity pass.
"""
import argparse

from benchmarks.common import emit, timed
from repro.configs.base import DagFLConfig
from repro.core import stability
from repro.net import topology as topo
from repro.net.events import simulate_insystem_tips

GRID = ((2, 5), (3, 5), (4, 6))
INSYSTEM_NODES = 16       # L0 depends on lambda and h, not N — a small full
                          # overlay keeps the union exact and the sim cheap
INSYSTEM_SYNC = 0.05      # delivery interval << h: staleness bias ~ interval


def run(seed: int = 0, insystem: bool = True, insystem_horizon: float = 2000.0):
    rows = {}
    for k, alpha in GRID:
        cfg = DagFLConfig(num_nodes=100, alpha=alpha, k=k)
        f = 1.5e9
        pred = stability.equilibrium_tips(cfg, f)
        with timed() as t:
            trace = stability.simulate_tip_count(cfg, horizon=2000.0, seed=seed, f=f)
        sim = trace.tail_mean(0.5)
        emit(
            f"stability/eq4/k{k}_alpha{alpha}",
            t["s"] * 1e6,
            f"L0_pred={pred:.2f};L0_sim={sim:.2f};rel_err={abs(sim-pred)/pred:.3f}",
        )
        ins = None
        if insystem:
            h = stability.iteration_delay(cfg, f)
            with timed() as t:
                tr = simulate_insystem_tips(
                    topo.full(INSYSTEM_NODES), h=h,
                    arrival_rate=cfg.arrival_rate, k=k, tau_max=cfg.tau_max,
                    horizon=insystem_horizon, capacity=256, seed=seed,
                    sync_period=INSYSTEM_SYNC,
                )
            ins = tr.tail_mean(0.5)
            emit(
                f"stability/eq4_insystem/k{k}_alpha{alpha}",
                t["s"] * 1e6,
                f"L0_pred={pred:.2f};L0_insystem={ins:.2f};"
                f"rel_err={abs(ins-pred)/pred:.3f};"
                f"published={tr.published};overflow={tr.overflow};"
                f"staleness_max={tr.staleness.max() if len(tr.staleness) else 0:.0f}",
            )
        rows[k] = (pred, sim, ins)
    return rows


if __name__ == "__main__":
    from benchmarks.common import header

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="short in-system horizon (sanity, noisier tail)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    header()
    run(seed=args.seed, insystem_horizon=400.0 if args.quick else 2000.0)
