"""Paper Figs. 6-11: accuracy under lazy / poisoning / backdoor nodes.

Fig. 6  — DAG-FL with 5/10/20 abnormal nodes (per type): insensitive.
Figs. 7-10 — four systems with 20% lazy / poisoning nodes:
  * lazy barely hurts DAG-FL/Google/Async; Block FL degrades,
  * poisoning hurts Google/Async badly; DAG-FL best (isolation).
Fig. 11 — backdoor: all systems keep clean accuracy (the attack is targeted).
"""
from benchmarks.common import emit, fmt_curve, timed
from repro.fl.experiments import abnormal_experiment


def run_dagfl_sweep(task_name="cnn", iterations=300, seed=0, counts=(5, 10, 20)):
    """Fig. 6: DAG-FL only, all three abnormal types, varying counts."""
    for abnormal in ("lazy", "poisoning", "backdoor"):
        if abnormal == "backdoor" and task_name != "cnn":
            continue
        for n in counts:
            with timed() as t:
                res = abnormal_experiment(
                    task_name, abnormal, n, iterations, seed, systems=("dagfl",)
                )["dagfl"]
            emit(
                f"fig6/{task_name}/dagfl/{abnormal}/{n}",
                (t["s"] / iterations) * 1e6,
                f"final_acc={res.accs[-1]:.3f};curve={fmt_curve(res.iters, res.accs)}",
            )


def run_four_systems(task_name="cnn", abnormal="lazy", num=20, iterations=300, seed=0):
    """Figs. 7-10: all four systems at 20% abnormal."""
    with timed() as t:
        res = abnormal_experiment(task_name, abnormal, num, iterations, seed)
    for name, r in res.items():
        extra = f"final_acc={r.accs[-1]:.3f};curve={fmt_curve(r.iters, r.accs)}"
        if "attack_success" in r.extras:
            extra += f";attack_success={r.extras['attack_success']:.4f}"
        emit(f"fig7_10/{task_name}/{abnormal}{num}/{name}",
             (t["s"] / iterations) * 1e6, extra)
    return res
