"""Benchmark harness: one module per paper table/figure.

    python -m benchmarks.run [--quick] [--only NAME]

Emits ``name,us_per_call,derived`` CSV rows (benchmarks.common.emit).
Scale note: the simulation benches run the paper's experiments at bench
scale (100 nodes, 300-400 iterations vs the paper's 5000-10000, scaled-down
CNN/LSTM on synthetic data) — trends and orderings are the reproduction
target; see EXPERIMENTS.md.
"""
import argparse
import subprocess
import sys
import time
import traceback

from benchmarks.common import header
from benchmarks import (
    ablation_weighted,
    fig5_ideal_convergence,
    fig6_11_abnormal_nodes,
    gossip_propagation,
    kernel_bench,
    roofline_table,
    serve_load,
    stability_tips,
    table2_iteration_delay,
    table3_attack_success,
    table4_contribution_rates,
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="reduced iteration counts")
    ap.add_argument("--only", help="run a single bench by prefix")
    args = ap.parse_args()

    # defaults sized for the CPU container (~45 min total); the paper-scale
    # sweep is the same code with larger counts (EXPERIMENTS.md notes scale)
    iters_long = 150 if args.quick else 250
    iters_mid = 100 if args.quick else 200
    # the LSTM task's sequential 80-step scan is ~4x the CNN cost per
    # iteration on CPU; its benches run shorter (trend-sufficient)
    iters_lstm = 60 if args.quick else 150
    counts = (20,) if args.quick else (5, 20)

    benches = [
        ("stability", lambda: stability_tips.run()),
        ("kernels", lambda: kernel_bench.run()),
        ("table2", lambda: (
            table2_iteration_delay.run("cnn", 100),
            table2_iteration_delay.run("lstm", 100),
        )),
        ("fig5", lambda: (
            fig5_ideal_convergence.run("cnn", iters_long),
            fig5_ideal_convergence.run("lstm", iters_lstm),
        )),
        ("fig6", lambda: fig6_11_abnormal_nodes.run_dagfl_sweep("cnn", iters_mid, counts=counts)),
        ("fig7_10", lambda: (
            fig6_11_abnormal_nodes.run_four_systems("cnn", "lazy", 20, iters_mid),
            fig6_11_abnormal_nodes.run_four_systems("cnn", "poisoning", 20, iters_mid),
            fig6_11_abnormal_nodes.run_four_systems("cnn", "backdoor", 20, iters_mid),
            fig6_11_abnormal_nodes.run_four_systems("lstm", "poisoning", 20, iters_lstm),
        )),
        # sync fast path: impl x N x cap round grid + dispatch batching,
        # written to BENCH_gossip_sync.json
        ("gossip_sync", lambda: gossip_propagation.run_sync_bench()),
        # continuous-time event engine: tick-limit equivalence, per-edge
        # latency propagation, in-system Eq. (4). Already part of
        # gossip_sync — the standalone entry exists only for targeted
        # --only runs, so a default full run doesn't execute it twice.
        *([("event_engine", lambda: gossip_propagation.run_event_engine())]
          if args.only else []),
        # in-loop telemetry: obs-off bitwise equivalence + collector
        # overhead. Already part of gossip_sync; same targeted-run rule.
        *([("observability", lambda: gossip_propagation.run_observability())]
          if args.only else []),
        # adversarial fault layer: all-honest bitwise equivalence + the
        # spoof-defense tripwire (BENCH_gossip_sync.json "attack_suite").
        # Already part of gossip_sync; same targeted-run rule.
        *([("attack_suite", lambda: gossip_propagation.run_fault_suite())]
          if args.only else []),
        # wire compression: identity-codec bitwise equivalence + the
        # accuracy-vs-bytes Pareto sweep (BENCH_gossip_sync.json
        # "delta_codec"). Already part of gossip_sync; same targeted-run
        # rule.
        *([("delta_codec", lambda: gossip_propagation.run_delta_codec())]
          if args.only else []),
        # Poisson inference load on the event engine: zero-rate bitwise
        # equivalence + requests/s and staleness-at-serve percentiles
        # across Table-I link classes and a partition arm
        # (BENCH_gossip_sync.json "serve_load"). Already part of
        # gossip_sync; same targeted-run rule.
        *([("serve_load", lambda: serve_load.run_serve_load())]
          if args.only else []),
        # demo: write a Perfetto trace + metrics JSONL from a small sim
        *([("obs_report", lambda: subprocess.check_call(
            [sys.executable, "scripts/obs_report.py", "--iterations", "10"]))]
          if args.only else []),
        ("gossip", lambda: (
            gossip_propagation.run_sweep(iters_mid),
            gossip_propagation.run_partition(iters_mid),
        )),
        ("table3", lambda: (
            table3_attack_success.run(iters_mid),
            table3_attack_success.run_transport(iters_mid // 4),
        )),
        ("table4", lambda: table4_contribution_rates.run("cnn", iters_mid, counts=counts)),
        ("ablation", lambda: ablation_weighted.run(150 if args.quick else 200)),
        ("roofline", lambda: roofline_table.run()),
    ]

    header()
    failures = []
    t0 = time.time()
    for name, fn in benches:
        if args.only and not name.startswith(args.only):
            continue
        try:
            fn()
        except Exception as e:
            failures.append((name, repr(e)))
            traceback.print_exc()
    print(f"# total_bench_time_s,{time.time()-t0:.1f}")
    if failures:
        for f in failures:
            print(f"# FAILED,{f[0]},{f[1]}")
        sys.exit(1)


if __name__ == "__main__":
    main()
