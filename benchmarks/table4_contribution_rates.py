"""Paper Table IV: contribution rates r0 (abnormal) vs r (all), m in {0,1}.

Paper claims validated: poisoning r0/r << 1 at both m; lazy nodes only
separable at m=1; detection degrades as the abnormal fraction grows.
"""
from benchmarks.common import emit, timed
from repro.fl.experiments import contribution_experiment


def run(task_name: str = "cnn", iterations: int = 300, seed: int = 0,
        counts=(5, 10, 20)):
    out = {}
    for abnormal in ("lazy", "poisoning", "backdoor"):
        if abnormal == "backdoor" and task_name != "cnn":
            continue
        for n in counts:
            with timed() as t:
                rows = contribution_experiment(task_name, abnormal, n, iterations, seed)
            for m, r in rows.items():
                emit(
                    f"table4/{task_name}/{abnormal}/{n}/m{m}",
                    (t["s"] / iterations) * 1e6,
                    f"r0={r['r0']:.3f};r={r['r']:.3f};ratio={r['ratio']:.3f}",
                )
            out[(abnormal, n)] = rows
    return out
