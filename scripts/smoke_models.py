"""Dev script: run every reduced arch through forward/train/prefill/decode."""
import sys

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, TrainConfig
from repro.models import build_model
from repro.optim import init_optimizer

B, S = 2, 16


def run(name):
    cfg = ARCHS[name].reduced()
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    n = sum(p.size for p in jax.tree_util.tree_leaves(params))
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.frontend_tokens:
        batch["frontend"] = jax.random.normal(key, (B, cfg.frontend_tokens, cfg.frontend_dim))

    logits, aux = model.forward(params, tokens, batch.get("frontend"))
    assert logits.shape == (B, S + cfg.frontend_tokens, cfg.vocab_size), logits.shape
    assert jnp.all(jnp.isfinite(logits)), "NaN in forward"

    tc = TrainConfig(optimizer="sgd", learning_rate=0.01)
    opt = init_optimizer(tc, params)
    p2, opt2, metrics = model.train_step(tc, params, opt, batch, 0.01)
    assert jnp.isfinite(metrics["loss"]), "NaN loss"

    # prefill + decode
    lg, cache = model.prefill(
        params, tokens, batch.get("frontend"), cache_len=S + cfg.frontend_tokens + 4
    )
    assert jnp.all(jnp.isfinite(lg))
    tok = tokens[:, -1:]
    lg2, cache = model.decode_step(params, tok, cache)
    assert lg2.shape == (B, 1, cfg.vocab_size)
    assert jnp.all(jnp.isfinite(lg2)), "NaN decode"
    print(f"OK {name:22s} params={n/1e6:6.2f}M loss={float(metrics['loss']):.3f}")


if __name__ == "__main__":
    names = sys.argv[1:] or sorted(ARCHS)
    for nm in names:
        run(nm)
