"""Docs CI check: execute fenced code snippets and verify relative links.

    python scripts/check_docs.py [files...]

Defaults to ``README.md``, every ``docs/*.md``, and ``benchmarks/README.md``.
Two checks, both against the INSTALLED package (CI runs this after
``pip install -e ".[test]"``, so a snippet that imports a module the package
no longer ships fails loudly):

* fenced ```python blocks are executed as scripts and ```bash blocks run
  under ``bash -euo pipefail``, each from the repo root. A block whose FIRST
  line contains ``docs: skip`` is exempt (reserved for illustrative or
  expensive commands — the full test suite, multi-minute sims); everything
  else must exit 0.
* every relative markdown link ``[text](path)`` must point at a file or
  directory that exists (anchors and external http(s)/mailto links are
  ignored), so renames cannot silently strand the docs.

Exit 0 iff every snippet ran and every link resolves.
"""
from __future__ import annotations

import re
import subprocess
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
FENCE = re.compile(r"^```(\w+)?\s*$")
LINK = re.compile(r"\[[^\]^\[]*\]\(([^)\s]+)\)")
SKIP_MARK = "docs: skip"


def extract_blocks(text: str):
    """Yield (language, first_line_no, source) for each fenced block."""
    lines = text.splitlines()
    i = 0
    while i < len(lines):
        m = FENCE.match(lines[i])
        if m and m.group(1):
            lang, start = m.group(1).lower(), i + 1
            body = []
            i += 1
            while i < len(lines) and not lines[i].startswith("```"):
                body.append(lines[i])
                i += 1
            yield lang, start + 1, "\n".join(body)
        i += 1


def run_block(lang: str, src: str) -> subprocess.CompletedProcess:
    if lang == "python":
        cmd = [sys.executable, "-c", src]
    else:
        cmd = ["bash", "-euo", "pipefail", "-c", src]
    return subprocess.run(
        cmd, cwd=REPO, capture_output=True, text=True, timeout=1200
    )


def check_file(path: Path) -> list:
    errors = []
    text = path.read_text()
    rel = path.relative_to(REPO)

    for lang, line, src in extract_blocks(text):
        if lang not in ("python", "bash", "sh"):
            continue
        first = src.lstrip().splitlines()[0] if src.strip() else ""
        if SKIP_MARK in first:
            print(f"  {rel}:{line} [{lang}] skipped (marked)")
            continue
        proc = run_block("python" if lang == "python" else "bash", src)
        status = "ok" if proc.returncode == 0 else f"FAILED ({proc.returncode})"
        print(f"  {rel}:{line} [{lang}] {status}")
        if proc.returncode != 0:
            errors.append(
                f"{rel}:{line}: {lang} snippet failed\n"
                f"--- stderr ---\n{proc.stderr[-2000:]}"
            )

    for m in LINK.finditer(text):
        target = m.group(1)
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        target_path = (path.parent / target.split("#")[0]).resolve()
        if not target_path.exists():
            errors.append(f"{rel}: broken relative link -> {target}")
    return errors


def main(argv) -> int:
    if argv:
        files = [Path(a).resolve() for a in argv]
    else:
        files = [REPO / "README.md", REPO / "benchmarks" / "README.md"]
        files += sorted((REPO / "docs").glob("*.md"))
    errors = []
    for f in files:
        if not f.exists():
            errors.append(f"missing doc file: {f}")
            continue
        print(f"# {f.relative_to(REPO)}")
        errors.extend(check_file(f))
    if errors:
        print("\n== DOCS CHECK FAILED ==")
        for e in errors:
            print(e)
        return 1
    print("\n# docs ok")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
