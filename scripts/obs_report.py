"""Export gossip-overlay telemetry to files: Perfetto trace + metrics JSONL.

    python scripts/obs_report.py [--nodes N] [--iterations I]
                                 [--engine ticks|events] [--bank]
                                 [--percentiles] [--out-prefix PREFIX]

Runs a small ``run_dagfl_gossip`` simulation with the in-loop collectors on
(``repro.obs``) and writes

* ``PREFIX.trace.json`` — Chrome Trace Event JSON. Open it at
  https://ui.perfetto.dev (or ``chrome://tracing``): one track per node
  showing iteration spans, row deliveries, and bank chunk drains, plus an
  overlay control track with partition windows (and, with
  ``--percentiles``, one ``hist:`` counter track per latency histogram);
* ``PREFIX.metrics.jsonl`` — one summary line (rounds, dispatch counts,
  final byte/staleness snapshot) followed by one line per in-loop sample
  (t, tips, staleness, rows_delta, chunk_lag, bytes_total). With
  ``--percentiles`` one ``"kind": "hist"`` line per histogram precedes
  the samples.

``--percentiles`` arms the streaming latency histograms
(``ObsConfig(hist=HistConfig())``) and prints a p50/p95/p99 summary per
histogram — publish->first-merge, publish->commit, chunk transfer delay
— with the bin-resolution error bound on each value.

The collectors run INSIDE the jitted loops as scan/while-loop carries, so
the export reflects exactly what the device executed — and the run is
bitwise identical to an uninstrumented one (see docs/OBSERVABILITY.md).

By default outputs land under ``bench_artifacts/`` (untracked — bench
sample artifacts are never committed); pass an explicit ``--out-prefix``
to write elsewhere.
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--nodes", type=int, default=8)
    ap.add_argument("--iterations", type=int, default=12)
    ap.add_argument("--engine", choices=("ticks", "events"), default="events")
    ap.add_argument("--bank", action="store_true",
                    help="gossip the model bank too (adds chunk-drain events)")
    ap.add_argument("--percentiles", action="store_true",
                    help="arm the streaming histograms and print the "
                         "p50/p95/p99 ladder per latency histogram")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out-prefix",
                    default=os.path.join("bench_artifacts", "obs_sample"))
    args = ap.parse_args()

    from repro.fl.experiments import default_dagfl_config, make_cnn_setup
    from repro.fl.systems import SimConfig, run_dagfl_gossip
    from repro.net import gossip as gossip_lib
    from repro.net import topology as topo
    from repro.net.bank import BankGossipConfig
    from repro.obs import (HistConfig, ObsConfig, write_chrome_trace,
                           write_metrics_jsonl)

    n = args.nodes
    dcfg = default_dagfl_config(num_nodes=n)
    sim = SimConfig(iterations=args.iterations,
                    eval_every=max(args.iterations // 4, 1), seed=args.seed)
    task, nodes, gval, _ = make_cnn_setup(num_nodes=n, seed=args.seed)
    res = run_dagfl_gossip(
        task, nodes, dcfg, sim, gval,
        topology=topo.ring(n, link_latency=1.0, seed=args.seed),
        gossip=gossip_lib.GossipConfig(sync_period=1.0, seed=args.seed),
        engine=args.engine,
        bank_gossip=BankGossipConfig(chunks_per_slot=4) if args.bank else None,
        obs=ObsConfig(hist=HistConfig() if args.percentiles else None),
    )
    report = res.extras["obs"]
    out_dir = os.path.dirname(args.out_prefix)
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
    trace_path = f"{args.out_prefix}.trace.json"
    jsonl_path = f"{args.out_prefix}.metrics.jsonl"
    write_chrome_trace(report, trace_path)
    write_metrics_jsonl(report, jsonl_path)
    print(f"engine={report.engine} rounds={report.rounds} "
          f"samples={len(report.series['t'])} "
          f"trace_events={len(report.trace['t'])} "
          f"trace_dropped={report.trace_dropped} "
          f"dispatch={report.dispatch_counts}")
    if args.percentiles:
        for name, summ in report.hist["percentiles"].items():
            print(f"hist {name}: samples={summ['samples']} "
                  f"p50={summ['p50']:.4g}±{summ['p50_err']:.2g} "
                  f"p95={summ['p95']:.4g}±{summ['p95_err']:.2g} "
                  f"p99={summ['p99']:.4g}±{summ['p99_err']:.2g}")
    print(f"wrote {trace_path} (load at https://ui.perfetto.dev)")
    print(f"wrote {jsonl_path}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
