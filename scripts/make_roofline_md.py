"""Render the dry-run jsonl artifacts into the EXPERIMENTS.md roofline table."""
import json
import os
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ART = os.path.join(REPO, "benchmarks", "artifacts")


def load(path):
    if not os.path.exists(path):
        return {}
    latest = {}
    for line in open(path):
        if line.strip():
            r = json.loads(line)
            latest[(r["arch"], r["shape"], r.get("opt", False))] = r
    return latest


def fmt_row(r):
    mf = r.get("model_flops", 0.0)
    return (
        f"| {r['arch']} | {r['shape']} | {r['step']} | "
        f"{r['t_compute_s']:.4f} | {r['t_memory_s']:.3f} | {r['t_collective_s']:.3f} | "
        f"**{r['dominant']}** | {r.get('useful_flops_ratio', 0):.3f} | "
        f"{r.get('peak_bytes', 0)/1e9:.1f} |"
    )


def main():
    single = load(os.path.join(ART, "dryrun_single.jsonl"))
    multi = load(os.path.join(ART, "dryrun_multi.jsonl"))

    lines = []
    lines.append("### Single pod (16x16 = 256 chips) — baseline, seconds/step/device\n")
    lines.append("| arch | shape | step | t_compute | t_memory | t_collective | dominant | useful/HLO | peak GB/dev |")
    lines.append("|---|---|---|---|---|---|---|---|---|")
    for key in sorted(single):
        lines.append(fmt_row(single[key]))
    if multi:
        lines.append("\n### Multi-pod (2x16x16 = 512 chips) — compile proof + terms\n")
        lines.append("| arch | shape | step | t_compute | t_memory | t_collective | dominant | useful/HLO | peak GB/dev |")
        lines.append("|---|---|---|---|---|---|---|---|---|")
        for key in sorted(multi):
            lines.append(fmt_row(multi[key]))
    table = "\n".join(lines)

    out = os.path.join(ART, "roofline_single.md")
    with open(out, "w") as f:
        f.write(table + "\n")
    print(f"wrote {out}")

    exp = os.path.join(REPO, "EXPERIMENTS.md")
    text = open(exp).read()
    marker = "<!-- ROOFLINE_TABLE -->"
    if marker in text:
        text = text.replace(marker, table, 1)
        open(exp, "w").write(text)
        print("inserted table into EXPERIMENTS.md")
    else:
        print("marker not found in EXPERIMENTS.md (already filled?)")


if __name__ == "__main__":
    main()
